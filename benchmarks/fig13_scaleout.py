"""Fig. 13 (repo extension): 100-engine scale-out replay on the
event-heap continuum clock.

The tentpole question: with ``Cluster.advance_to`` replaying engine
ticks in global event order off a wake-time heap — O(events on *active*
engines) instead of the old lockstep sweep over every handle — can the
harness replay tens of thousands of requests against a 100+ engine
fleet, and what do fleet-level routing policies buy at that scale?

The fleet runs the analytic ``SimEngine`` backend
(``build_continuum(backend="sim")``): no weights, no XLA, the same
profiled per-tick costs — so the policies below are priced by exactly
the roofline the live engines charge.

Four dispatch policies over one Poisson arrival trace (MIOBench tasks,
sessions sharing prompt prefixes, ``taskgen.poisson_arrivals`` /
``session_ids``):

  * **greedy**          — argmin of (estimated service + tracked
                          backlog) over all engines;
  * **hedged**          — greedy, plus a duplicate dispatch to the
                          next-best engine for predicted-tail requests
                          (chosen total well above the running mean);
                          first finisher wins;
  * **prefix_affinity** — session-sticky: a conversation returns to the
                          engine holding its prefix KV unless that
                          engine's backlog spills past the fleet's best
                          by a threshold;
  * **qlmio**           — the paper's utility (latency ratio + quality
                          completion bonus, Eq. 21 shape) with a
                          prefix-reuse discount on the sticky engine.

plus **qlmio_stream**: the qlmio replay resubmitted with per-token
streaming (``ContinuumRequest(stream=True)``) — tokens are yielded as
they decode and the tail pays one streamed chunk's downlink instead of
the full response payload, which is the measured TTFT/e2e win of the
streaming front end.

CI-smoke entry: ``python benchmarks/fig13_scaleout.py --smoke`` replays
10k requests over 100 engines in seconds on CPU, asserts the O(active)
property (an identical trace touching 3 engines charges the *same*
number of handle steps on a 10-engine and a 100-engine fleet), and that
streaming strictly improves measured TTFT.  ``--trace out.json``
additionally exports a Perfetto trace of a small traced replay.
"""
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit  # noqa: E402
from benchmarks.fig10_continuum_replay import analytic_predictors  # noqa: E402

from repro.data.taskgen import poisson_arrivals, session_ids  # noqa: E402
from repro.serving.cluster import Cluster, build_continuum  # noqa: E402
from repro.serving.request import ContinuumRequest  # noqa: E402
from repro.serving.telemetry import Telemetry  # noqa: E402
from repro.sim.miobench import SERVER_CLASSES, generate  # noqa: E402

BUDGETS = {
    # 44 + 44 + 12 = 100 engines; ~10k Poisson arrivals in smoke
    "smoke": dict(spec=[(0, 44), (1, 44), (2, 12)], n_requests=10_000,
                  n_tasks=400, sessions=300, load=0.85, decode_cap=8,
                  prompt_cap=40, trace_requests=400),
    "fast": dict(spec=[(0, 44), (1, 44), (2, 12)], n_requests=30_000,
                 n_tasks=3377, sessions=900, load=0.65, decode_cap=10,
                 prompt_cap=48, trace_requests=800),
    "paper": dict(spec=[(0, 88), (1, 88), (2, 24)], n_requests=100_000,
                  n_tasks=3377, sessions=2500, load=0.7, decode_cap=12,
                  prompt_cap=48, trace_requests=1500),
}

PREFIX_LEN = 32      # session-shared prompt prefix (2 sim-engine pages)
PAGE = 16            # sim-engine prefix granularity
W_QUALITY = 2.0      # quality weight of the qlmio utility
HEDGE_FACTOR = 1.25  # hedge when the pick's total > factor x running mean
SPILL_S = 0.5        # prefix affinity yields past this backlog spill
MAX_BATCH = 4


def build_fleet(spec, telemetry=None):
    handles = build_continuum(spec, backend="sim", telemetry=telemetry,
                              max_batch=MAX_BATCH, max_seq=128,
                              page_size=PAGE)
    return Cluster(handles), handles


def make_trace(b, handles, bench, seed=0):
    """(tasks, arrivals, sessions, prompts, budgets): one shared trace
    replayed identically under every policy."""
    rng = np.random.default_rng(seed)
    n = b["n_requests"]
    tasks = rng.integers(0, bench.tasks.n, n)
    sess = session_ids(n, b["sessions"], seed)
    vocab = handles[0].cfg.vocab
    prompts = []
    for k in range(n):
        pre = np.random.default_rng(9_000_001 * (int(sess[k]) + 1))
        body = np.random.default_rng(1_000_003 * (int(tasks[k]) + 1))
        L = int(np.clip(bench.tasks.text_len[tasks[k]], 8, b["prompt_cap"]))
        prompts.append(np.concatenate([
            pre.integers(0, vocab, PREFIX_LEN),
            body.integers(0, vocab, L)]).astype(np.int32))
    budgets = np.clip(
        (bench.tasks.difficulty[tasks] * b["decode_cap"]).round(), 2,
        b["decode_cap"]).astype(np.int64)
    # offered load calibrated to the fleet: mean service / engines / load
    dtick = np.array([h.decode_tick_s for h in handles])
    ptok = np.array([h.prefill_tok_s for h in handles])
    mean_service = float(np.mean(
        [len(p) for p in prompts]) * ptok.mean()
        + budgets.mean() * dtick.mean() / MAX_BATCH)
    rate = len(handles) * b["load"] / max(mean_service, 1e-9)
    arrivals = poisson_arrivals(n, rate, seed + 1)
    return tasks, arrivals, sess, prompts, budgets, rate


def replay(policy, cluster, handles, bench, trace, b_hat, *,
           stream=False, consume_stream=False):
    """Run one policy over the shared trace; returns summary metrics.
    Dispatch bookkeeping is policy-side numpy (service estimate +
    tracked backlog per engine, drained with the clock) — the router's
    view, deliberately cheaper than probing 100 live queues per
    request."""
    tasks, arrivals, sess, prompts, budgets, _ = trace
    cluster.reset()
    n_handles = len(handles)
    cls = np.array([SERVER_CLASSES.index((h.device.name, h.profile.name))
                    for h in handles])
    dtick = np.array([h.decode_tick_s for h in handles])
    ptok = np.array([h.prefill_tok_s for h in handles])
    link = np.array([h.up_s + h.down_s for h in handles])
    backlog = np.zeros(n_handles)
    sticky: dict[int, int] = {}
    t_prev = 0.0
    uid_of = {}
    hedge_of = {}
    n_hedges = 0
    ema_total = None  # running mean of chosen totals (hedge trigger)
    for k in range(len(tasks)):
        t, task = float(arrivals[k]), int(tasks[k])
        backlog = np.maximum(0.0, backlog - (t - t_prev))
        t_prev = t
        # what the request costs end-to-end on each engine (scoring) vs.
        # how long it *occupies* the engine (backlog): decode shares the
        # engine max_batch-wide and links never serialize the queue
        service = len(prompts[k]) * ptok + int(budgets[k]) * dtick + link
        occupancy = (len(prompts[k]) * ptok
                     + int(budgets[k]) * dtick / MAX_BATCH)
        s_sticky = sticky.get(int(sess[k]))
        if policy == "prefix_affinity" and s_sticky is not None:
            total = service + backlog
            s = (s_sticky if backlog[s_sticky] <= backlog.min() + SPILL_S
                 else int(np.argmin(total)))
        elif policy == "qlmio":
            disc = np.zeros(n_handles)
            if s_sticky is not None:  # prefix KV already resident there
                disc[s_sticky] = (PREFIX_LEN // PAGE) * PAGE * ptok[s_sticky]
            total = np.maximum(service - disc, 1e-9) + backlog
            u = (-total / max(total.min(), 1e-9)
                 + W_QUALITY * (3.0 * b_hat[task, cls] - 2.0))
            s = int(np.argmax(u))
        else:  # greedy / hedged
            total = service + backlog
            s = int(np.argmin(total))
        sticky[int(sess[k])] = s
        quality_ok = int(bench.score[task, int(cls[s])]) == 1
        creq = ContinuumRequest(
            tokens=prompts[k], max_new_tokens=int(budgets[k]), arrival_s=t,
            task=task, quality_ok=quality_ok, server=s,
            stream=True if stream else None,
            predicted_s=float(service[s] + backlog[s]))
        uid_of[k] = cluster.submit(creq)
        total_s = float(service[s] + backlog[s])
        backlog[s] += occupancy[s]
        if policy == "hedged":
            # predicted-tail request: the chosen total dwarfs the running
            # mean — duplicate to the runner-up, first finisher wins
            if ema_total is not None and total_s > HEDGE_FACTOR * ema_total:
                alt = service + backlog
                alt[s] = np.inf
                s2 = int(np.argmin(alt))
                quality2 = int(bench.score[task, int(cls[s2])]) == 1
                hedge_of[k] = cluster.submit(creq.with_plan(
                    server=s2, predicted_s=float(alt[s2]),
                    quality_ok=quality2))
                backlog[s2] += occupancy[s2]
                n_hedges += 1
            ema_total = (total_s if ema_total is None
                         else 0.98 * ema_total + 0.02 * total_s)
        # keep the replay pipelined: serve what arrived so far
        if k % 64 == 0:
            if consume_stream:
                for _ in cluster.stream(t):
                    pass
            else:
                cluster.advance_to(t)
    cluster.drain()
    if consume_stream:
        for _ in cluster.stream(cluster.t):
            pass
    recs = {r["uid"]: r for r in cluster.collect()}
    e2e, ttft, succ = [], [], []
    for k in range(len(tasks)):
        r = recs[uid_of[k]]
        hk = hedge_of.get(k)
        if hk is not None:  # first finisher wins
            r2 = recs[hk]
            if r2["e2e_s"] < r["e2e_s"]:
                r = r2
        e2e.append(r["e2e_s"])
        ttft.append(r["ttft_s"])
        succ.append(r["success"])
    reused = int(sum(h.engine.metrics.counter("prefix_tokens_reused").value
                     for h in handles))
    return {"mean_e2e_s": float(np.mean(e2e)),
            "p95_e2e_s": float(np.percentile(e2e, 95)),
            "mean_ttft_s": float(np.mean(ttft)),
            "completion_rate": float(np.mean(succ)),
            "timeout_rate": float(np.mean(
                [recs[uid_of[k]]["timeout"] for k in range(len(tasks))])),
            "prefix_tokens_reused": reused,
            "n_hedges": int(n_hedges),
            "handle_steps": int(cluster.handle_steps),
            "heap_pops": int(cluster.heap_pops)}


def oactive_probe(trace_seed=7, n_requests=200):
    """The O(active) acceptance probe: replay one deterministic trace that
    only ever touches engines 0-2, on a 10-engine and a 100-engine fleet.
    Event-heap advancement must charge the *same* handle steps on both —
    idle engines cost nothing — where the old lockstep sweep scaled with
    fleet size."""
    steps = {}
    for n_cls0 in (8, 88):
        cluster, handles = build_fleet([(0, n_cls0), (1, 1), (2, 1)])
        rng = np.random.default_rng(trace_seed)
        for i in range(n_requests):
            s = int(rng.integers(0, 3))
            toks = rng.integers(0, handles[s].cfg.vocab, 24).astype(np.int32)
            cluster.submit(ContinuumRequest(
                tokens=toks, max_new_tokens=6, arrival_s=0.01 * i, task=i,
                server=s))
        cluster.drain()
        assert all(not r["timeout"] for r in cluster.collect())
        steps[len(handles)] = int(cluster.handle_steps)
    return steps


def traced_replay(b, bench, b_hat, trace, trace_path):
    """Small traced rerun of the qlmio policy for the CI artifact: the
    Perfetto export carries queue/prefill/decode/stream spans and the
    per-engine queue_depth counter the trace report's queue-wait section
    reads."""
    tm = Telemetry(trace=True)
    cluster, handles = build_fleet(b["spec"], telemetry=tm)
    n = b["trace_requests"]
    small = tuple(x[:n] for x in trace[:5]) + (trace[5],)
    replay("qlmio", cluster, handles, bench, small, b_hat,
           stream=True, consume_stream=True)
    tm.export(trace_path)
    n_stream = sum(e.get("name") == "stream" for e in tm.tracer.events)
    print(f"fig13,trace,{trace_path},stream_spans,{n_stream}")


def run():
    budget = "smoke" if "--smoke" in sys.argv[1:] else \
        os.environ.get("BENCH_BUDGET", "smoke")
    argv = sys.argv[1:]
    trace_path = argv[argv.index("--trace") + 1] if "--trace" in argv \
        else None
    b = BUDGETS[budget]
    t0 = time.time()
    bench = generate(seed=0, n_tasks=b["n_tasks"])
    _, b_hat = analytic_predictors(bench)
    cluster, handles = build_fleet(b["spec"])
    trace = make_trace(b, handles, bench, seed=0)
    print(f"fig13,fleet,{len(handles)}_sim_engines,requests,"
          f"{b['n_requests']},rate_per_s,{trace[5]:.1f},"
          f"build_s,{time.time() - t0:.2f}")

    results = {}
    print("fig13,policy,mean_e2e_s,p95_e2e_s,mean_ttft_s,completion,"
          "handle_steps,wall_s")
    for name in ("greedy", "hedged", "prefix_affinity", "qlmio"):
        t1 = time.time()
        r = replay(name, cluster, handles, bench, trace, b_hat)
        r["wall_s"] = time.time() - t1
        results[name] = r
        print(f"fig13,{name},{r['mean_e2e_s']:.3f},{r['p95_e2e_s']:.3f},"
              f"{r['mean_ttft_s']:.3f},{r['completion_rate']:.3f},"
              f"{r['handle_steps']},{r['wall_s']:.2f}")
    t1 = time.time()
    rs = replay("qlmio", cluster, handles, bench, trace, b_hat,
                stream=True, consume_stream=True)
    rs["wall_s"] = time.time() - t1
    results["qlmio_stream"] = rs
    print(f"fig13,qlmio_stream,{rs['mean_e2e_s']:.3f},{rs['p95_e2e_s']:.3f},"
          f"{rs['mean_ttft_s']:.3f},{rs['completion_rate']:.3f},"
          f"{rs['handle_steps']},{rs['wall_s']:.2f}")

    oactive = oactive_probe()
    fleets = sorted(oactive)
    print(f"fig13,oactive,steps_{fleets[0]}_engines,{oactive[fleets[0]]},"
          f"steps_{fleets[1]}_engines,{oactive[fleets[1]]}")

    q, g = results["qlmio"], results["greedy"]
    ttft_gain = 1.0 - rs["mean_ttft_s"] / max(q["mean_ttft_s"], 1e-9)
    print(f"fig13,headline,stream_ttft_reduction,{ttft_gain:.3f},"
          f"wall_s,{time.time() - t0:.1f}")
    emit("fig13_scaleout", {"fig13": {
        "n_engines": len(handles),
        "n_requests": b["n_requests"],
        "results": results,
        "qlmio_mean_e2e_s": q["mean_e2e_s"],
        "qlmio_completion": q["completion_rate"],
        "stream_ttft_reduction": ttft_gain,
        "oactive_steps_small": oactive[fleets[0]],
        "oactive_steps_large": oactive[fleets[1]],
    }})
    if trace_path is not None:
        traced_replay(b, bench, b_hat, trace, trace_path)

    # acceptance: the event heap is O(active) — same trace, same steps,
    # regardless of how many idle engines the fleet carries
    assert oactive[fleets[0]] == oactive[fleets[1]], oactive
    # streaming shortens measured TTFT (tail pays a token chunk, not the
    # full payload downlink) and never costs completions
    assert rs["mean_ttft_s"] < q["mean_ttft_s"]
    assert rs["completion_rate"] >= q["completion_rate"] - 1e-9
    # quality-aware routing completes at least as much as pure greedy
    assert q["completion_rate"] >= g["completion_rate"] - 0.02
    # session affinity actually reuses prefixes
    assert (results["prefix_affinity"]["prefix_tokens_reused"]
            > g["prefix_tokens_reused"])
    return results


if __name__ == "__main__":
    run()
