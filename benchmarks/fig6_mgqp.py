"""Fig. 6: MGQP training convergence (Focal loss + accuracy, train/val)."""
from benchmarks.common import emit, trained_predictors, world


def run():
    bench, feats, split_ids = world()
    _, _, _, hist_mgqp = trained_predictors(bench, feats, split_ids)
    print("fig6,epoch,train_loss,train_acc,val_acc")
    for h in hist_mgqp:
        print(f"fig6,{h['epoch']},{h['train_loss']:.4f},"
              f"{h['train_acc']:.4f},{h['val_acc']:.4f}")
    best = max(h["val_acc"] for h in hist_mgqp)
    print(f"fig6,best_val_acc,{best:.4f} (paper: 0.8546)")
    emit("fig6_mgqp", {"history": hist_mgqp})
    return hist_mgqp


if __name__ == "__main__":
    run()
